"""LM traffic-serving benchmark: token-level continuous batching vs the
static fixed-batch refill baseline on one seeded mixed-length trace.
Writes BENCH_lm_traffic.json — the LM decode twin of BENCH_traffic.json,
sharing its latency-summary schema (serve.metrics).

    PYTHONPATH=src python benchmarks/bench_lm_traffic.py [--requests 60]
    PYTHONPATH=src python benchmarks/bench_lm_traffic.py --scenario bursty

Both modes run on the SAME warmed `BucketedLMEngine` pool — "static" is a
host-side gang-refill admission policy, not a different engine — so the
tokens/s comparison carries zero compile-count confounds. The default load
is an overload (utilization 1.5× the calibrated full-occupancy request
capacity): continuous admission keeps decode slots busy where gang refill
drains them, which is the structural win the CI gate
(benchmarks/check_lm_traffic.py) asserts as continuous >= static tokens/s,
alongside zero recompiles after warmup, prefill program count == engines ×
prompt buckets, bit-identical seeded replay (dispatch, tokens, logits), and
per-request logits bit-identical to a batch=1 serial oracle on the same
engine (`one_vs_n_bit_identical_logits` — the MoE shiftadd arm included,
served at the generous no-drop capacity).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serve.frontend import lm_traffic_sweep
from repro.serve.traffic import SCENARIOS


def run(scenario="poisson", requests=60, seed=0, replicas=1, slots=4,
        buckets=(4, 8, 16), chunk=4, layers=2, d_model=64, vocab=256,
        utilization=1.5, verify=True):
    return lm_traffic_sweep(
        scenario=scenario, policies=("stage1", "shiftadd"),
        n_requests=requests, seed=seed, n_replicas=replicas, n_slots=slots,
        prompt_buckets=tuple(buckets), chunk=chunk, layers=layers,
        d_model=d_model, vocab_size=vocab, utilization=utilization,
        verify_replay=verify, verify_serial_oracle=verify)


def _print_record(rec):
    for name, r in rec["policies"].items():
        c, s = r["continuous"], r["static"]
        print(f"{name:>9}: continuous {c['tokens_per_s']:8.1f} tok/s "
              f"(occ {c['chunk_occupancy']:.2f})  static "
              f"{s['tokens_per_s']:8.1f} tok/s (occ "
              f"{s['chunk_occupancy']:.2f})  ratio "
              f"{r['continuous_vs_static_tokens_per_s']:.3f}x  "
              f"ttft p50 {c['ttft']['p50_s'] * 1e3:.1f} ms  "
              f"recompiles {c['recompiles_after_warmup']}"
              f"/{s['recompiles_after_warmup']}")
        if "one_vs_n_bit_identical_logits" in r:
            print(f"{'':>9}  verify [replay={r['replay_bit_identical_logits']}"
                  f" 1vsN={r['one_vs_n_bit_identical_logits']}"
                  f" compared={r['one_vs_n_compared']}]")


def main(rows=None):
    if rows is not None:
        # benchmarks/run.py harness mode: tiny geometry, CSV row contract.
        rec = run(requests=16, slots=2, buckets=(4, 8), layers=2, d_model=32,
                  vocab=64, verify=False)
        for name, r in rec["policies"].items():
            c = r["continuous"]
            rows.append((f"lm_traffic_{name}_ttft_p50",
                         c["ttft"]["p50_s"] * 1e6,
                         f"cont_vs_static="
                         f"{r['continuous_vs_static_tokens_per_s']:.2f}x"))
        return

    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="poisson", choices=SCENARIOS)
    ap.add_argument("--requests", type=int, default=60)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=1)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--buckets", type=int, nargs="+", default=[4, 8, 16])
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--d-model", type=int, default=64)
    ap.add_argument("--vocab", type=int, default=256)
    ap.add_argument("--utilization", type=float, default=1.5)
    ap.add_argument("--skip-verify", action="store_true",
                    help="omit the replay + batch=1 oracle verification")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_lm_traffic.json")

    rec = run(scenario=args.scenario, requests=args.requests, seed=args.seed,
              replicas=args.replicas, slots=args.slots, buckets=args.buckets,
              chunk=args.chunk, layers=args.layers, d_model=args.d_model,
              vocab=args.vocab, utilization=args.utilization,
              verify=not args.skip_verify)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    _print_record(rec)
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
