"""Paper Tab. 4/6: component-wise breakdown of ShiftAddViT variants.

Per variant (MSA → +LinearAttn → +Add(Quant) → +Shift → +MoE) reports the
v5e roofline-model latency of one DeiT-T-like forward (batch 32) plus the
45 nm analytic energy — the two axes of the paper's breakdown tables.
"""
from __future__ import annotations

from repro.core import energy
from repro.core.energy import HBM_BW, PEAK_FLOPS_BF16, PEAK_OPS_INT8

SPEC = dict(n_layers=12, d_model=192, n_heads=3, d_ff=768, tokens=197,
            batch=32)


def _lin_time(m, k, n, kind):
    flops = 2.0 * m * k * n
    if kind == "dense":
        return max(flops / PEAK_FLOPS_BF16, (m * k + k * n + m * n) * 2 / HBM_BW)
    # shift / add: int8 second operand, int8 MXU rate
    return max(flops / PEAK_OPS_INT8, (m * k * 2 + k * n + m * n * 2) / HBM_BW)


def variant_time(attn, proj, mlp):
    s = SPEC
    b, L, d, h, f, n = (s["batch"], s["n_layers"], s["d_model"], s["n_heads"],
                        s["d_ff"], s["tokens"])
    dh = d // h
    t = 0.0
    e = energy.OpEnergy(0, 0)
    for _ in range(L):
        for _ in range(4):
            t += _lin_time(b * n, d, d, proj)
            e += (energy.shift_matmul_energy(b * n, d, d) if proj == "shift"
                  else energy.matmul_energy(b * n, d, d, "fp16"))
        if attn == "msa":
            t += _lin_time(b * h * n, dh, n, "dense")
            t += _lin_time(b * h * n, n, dh, "dense")
            e += energy.matmul_energy(b * h * n, dh, n)
            e += energy.matmul_energy(b * h * n, n, dh)
        else:  # linear order Q(KV); "add" binarizes the contractions
            kind = "add" if attn == "add" else "dense"
            t += _lin_time(b * h * dh, n, dh, kind)
            t += _lin_time(b * h * n, dh, dh, kind)
            fn = (energy.add_matmul_energy if attn == "add"
                  else lambda m, k, nn: energy.matmul_energy(m, k, nn, "fp16"))
            e += fn(b * h * dh, n, dh)
            e += fn(b * h * n, dh, dh)
        if mlp == "moe":
            t_shift = (_lin_time(int(b * n * 2 / 3), d, f, "shift")
                       + _lin_time(int(b * n * 2 / 3), f, d, "shift"))
            t_mult = (_lin_time(b * n - int(b * n * 2 / 3), d, f, "dense")
                      + _lin_time(b * n - int(b * n * 2 / 3), f, d, "dense"))
            t += max(t_shift, t_mult)       # parallel experts: max finish
            e += energy.shift_matmul_energy(int(b * n * 2 / 3), d, f)
            e += energy.shift_matmul_energy(int(b * n * 2 / 3), f, d)
            e += energy.matmul_energy(b * n - int(b * n * 2 / 3), d, f, "fp16")
            e += energy.matmul_energy(b * n - int(b * n * 2 / 3), f, d, "fp16")
        else:
            kind = "shift" if mlp == "shift" else "dense"
            t += _lin_time(b * n, d, f, kind)
            t += _lin_time(b * n, f, d, kind)
            fn = (energy.shift_matmul_energy if mlp == "shift"
                  else lambda m, k, nn: energy.matmul_energy(m, k, nn, "fp16"))
            e += fn(b * n, d, f)
            e += fn(b * n, f, d)
    return t, e.total_pj / 1e9


VARIANTS = [
    ("msa", ("msa", "dense", "dense")),
    ("linear_attn", ("linear", "dense", "dense")),
    ("la_add_quant", ("add", "dense", "dense")),
    ("la_add_shiftattn", ("add", "shift", "dense")),
    ("la_add_shift_both", ("add", "shift", "shift")),
    ("la_add_moe_both", ("add", "shift", "moe")),
]


def main(rows=None):
    own = rows is None
    rows = [] if own else rows
    base_t = base_e = None
    for name, (attn, proj, mlp) in VARIANTS:
        t, e = variant_time(attn, proj, mlp)
        if base_t is None:
            base_t, base_e = t, e
        rows.append((f"breakdown_{name}", t * 1e6,
                     f"latency_vs_msa={base_t / t:.2f}x;energy_mJ={e:.2f};"
                     f"energy_savings={1 - e / base_e:+.1%}"))
    if own:
        for r in rows:
            print(",".join(str(c) for c in r))
    return rows


if __name__ == "__main__":
    main()
