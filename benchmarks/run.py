"""Benchmark harness — one entry per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows. The roofline table (§Roofline)
is produced separately from the dry-run artifacts by benchmarks/roofline.py.

  bench_kernels      — paper Fig. 4/5 + App. A (MatShift / MatAdd)
  bench_breakdown    — paper Tab. 4/6 (variant latency/energy breakdown)
  bench_energy       — paper Tab. 3 / Fig. 3 (45 nm analytic energy)
  bench_vit          — serving policy sweep (BENCH_vit.json's small twin)
  bench_serve        — LM prefill/decode serving path (BENCH_serve.json's)
  bench_traffic      — traffic frontend p99/goodput (BENCH_traffic.json's)
  check_traffic      — its gate (crossover, router-vs-shiftadd, verify keys)
  bench_elastic      — elastic control plane: autoscale + faults + degrade
  check_elastic      — its gate (zero-miss, warm-pool invariant, replay)
  bench_lm_traffic   — LM continuous batching vs static refill
  check_lm_traffic   — its gate (throughput, recompiles, bit-identity)
  bench_sensitivity  — paper Tab. 2 (trains reduced ViTs; slowest)
  bench_llloss       — paper Tab. 7 (LL-loss ablation; trains routers)
  check_analysis     — serving-contract static analyzer (pass wall-times)
  check_vit_pallas   — impl=pallas arm gate (interpret-smoke on CPU)
"""
from __future__ import annotations

import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
# `from benchmarks import ...` needs the repo root too (namespace package;
# `python benchmarks/run.py` puts benchmarks/ itself at sys.path[0]).
sys.path.insert(1, os.path.join(os.path.dirname(__file__), ".."))


def main() -> None:
    from benchmarks import (bench_breakdown, bench_elastic, bench_energy,
                            bench_kernels, bench_llloss, bench_lm_traffic,
                            bench_sensitivity, bench_serve, bench_traffic,
                            bench_vit, check_analysis, check_elastic,
                            check_lm_traffic, check_traffic,
                            check_vit_pallas)

    rows = []
    for mod in (bench_kernels, bench_breakdown, bench_energy, bench_vit,
                bench_serve, bench_traffic, check_traffic, bench_elastic,
                bench_lm_traffic, bench_sensitivity, bench_llloss,
                check_analysis, check_elastic, check_lm_traffic,
                check_vit_pallas):
        t0 = time.time()
        mod.main(rows)
        rows.append((f"_{mod.__name__.split('.')[-1]}_wall",
                     (time.time() - t0) * 1e6, "harness"))
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
