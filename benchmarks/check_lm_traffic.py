"""CI gate for the LM continuous-batching benchmark (lm-traffic job).

    python benchmarks/check_lm_traffic.py BENCH_lm_traffic.json

Fails (exit 1) if, for any policy arm:
- continuous-batching decode throughput falls below the static fixed-batch
  refill baseline on the same trace (tokens/s, virtual clock — the win is
  structural: continuous admission can only keep slots busier than gang
  refill, so a regression here means the scheduler or the slot lifecycle
  broke, not that the machine was slow),
- either mode recompiled a program after warmup, or the pool traced more
  (or fewer) bucket-shaped prefill programs than engines × prompt buckets
  (the no-shape-leak contract: every prompt pads into a bucket, every
  decode chunk reuses ONE program),
- the two modes served different request sets (the throughput comparison
  would be vacuous), or anything was shed at the benchmark's unbounded
  admission queue,
- a verification field is false OR MISSING: bit-identical seeded replay
  (dispatch signature, tokens, logits) and the batch=1 serial oracle
  (`one_vs_n_*`: every request re-served ALONE on the same engine, in the
  slot the packed run used, must reproduce its packed-batch tokens and
  logits bit for bit — the token-level batch-invariance contract, MoE
  shiftadd arm included). A partial oracle
  comparison (compared < served) also fails: a coverage regression must not
  impersonate a pass.

As a harness module (benchmarks/run.py): `main(rows)` regenerates the tiny
verified record and appends one row with the gate verdict, so the gate's
cost and outcome ride along the benchmark CSV like the other check scripts.
"""
from __future__ import annotations

import json
import sys

VERIFY_KEYS = ("replay_identical_dispatch", "replay_bit_identical_tokens",
               "replay_bit_identical_logits", "one_vs_n_bit_identical_tokens",
               "one_vs_n_bit_identical_logits")


def gate_record(rec):
    """→ list of failure strings (empty = gate passes); prints a summary."""
    failures = []
    for name, r in rec.get("policies", {}).items():
        c, s = r["continuous"], r["static"]
        if c["tokens_per_s"] < s["tokens_per_s"]:
            failures.append(
                f"{name}: continuous {c['tokens_per_s']:.1f} tok/s below "
                f"static {s['tokens_per_s']:.1f} tok/s on the same trace")
        for mode, m in (("continuous", c), ("static", s)):
            if m["recompiles_after_warmup"] > 0:
                failures.append(f"{name}/{mode}: recompiled after warmup "
                                f"({m['recompiles_after_warmup']} traces)")
            if m["prefill_trace_count"] != m["expected_prefill_traces"]:
                failures.append(
                    f"{name}/{mode}: {m['prefill_trace_count']} prefill "
                    f"programs traced, expected "
                    f"{m['expected_prefill_traces']} (engines × buckets)")
            if m["shed_requests"] > 0:
                failures.append(f"{name}/{mode}: {m['shed_requests']} "
                                f"requests shed at an unbounded queue")
        if c["served_requests"] != s["served_requests"]:
            failures.append(f"{name}: modes served different request sets "
                            f"({c['served_requests']} vs "
                            f"{s['served_requests']})")
        for key in VERIFY_KEYS:
            if key not in r:
                failures.append(
                    f"{name}: {key} missing — the benchmark did not run the "
                    f"determinism verification (the gate may not be skipped)")
            elif not r[key]:
                failures.append(f"{name}: {key} is false — token-level "
                                f"serving is not deterministic/"
                                f"batch-invariant under this arm")
        if ("one_vs_n_compared" in r
                and r["one_vs_n_compared"] != c["served_requests"]):
            failures.append(
                f"{name}: batch=1 oracle comparison was partial — "
                f"{r['one_vs_n_compared']} of {c['served_requests']} "
                f"served requests compared")
        print(f"{name:>9}: cont {c['tokens_per_s']:8.1f} tok/s  static "
              f"{s['tokens_per_s']:8.1f} tok/s  ratio "
              f"{r.get('continuous_vs_static_tokens_per_s', 0.0):.3f}x  "
              f"recompiles {c['recompiles_after_warmup']}"
              f"/{s['recompiles_after_warmup']}  verify [replay="
              f"{r.get('replay_bit_identical_logits', 'absent')} 1vsN="
              f"{r.get('one_vs_n_bit_identical_logits', 'absent')}]")
    if not rec.get("policies"):
        failures.append("record has no policy arms")
    return failures


def main(rows) -> None:
    """benchmarks/run.py harness mode: tiny verified record, gate verdict."""
    import time

    try:
        from benchmarks import bench_lm_traffic
    except ImportError:          # standalone: benchmarks/ is sys.path[0]
        import bench_lm_traffic

    t0 = time.time()
    rec = bench_lm_traffic.run(requests=16, slots=2, buckets=(4, 8),
                               layers=2, d_model=32, vocab=64, verify=True)
    failures = gate_record(rec)
    rows.append(("lm_traffic_gate", (time.time() - t0) * 1e6,
                 f"failures={len(failures)}"))


def cli(argv):
    if len(argv) != 2:
        print(__doc__)
        return 2
    failures = gate_record(json.load(open(argv[1])))
    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        return 1
    print("lm-traffic gate OK")
    return 0


if __name__ == "__main__":
    sys.exit(cli(sys.argv))
