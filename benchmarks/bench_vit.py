"""ShiftAddViT policy-sweep serving benchmark. Writes BENCH_vit.json so the
paper's headline claim (latency + energy reduction vs the dense ViT) has a
per-PR trajectory, next to BENCH_serve.json's LM numbers.

    PYTHONPATH=src python benchmarks/bench_vit.py [--batch 32]
    PYTHONPATH=src python benchmarks/bench_vit.py --no-freeze   # A/B arm
    PYTHONPATH=src python benchmarks/bench_vit.py --breakdown   # per-component
    PYTHONPATH=src python benchmarks/bench_vit.py --impl interpret
    PYTHONPATH=src python benchmarks/bench_vit.py --tune TUNE_kernels.json

The record also carries a nested `pallas_arm`: a shiftadd-only sweep at
impl=pallas (real kernels on TPU, interpret-mode smoke at reduced geometry
elsewhere) next to an impl=xla twin at the same geometry, fed through the
persisted autotune table when `--tune` is given. check_vit_pallas.py gates
`pallas <= xla` per bucket on it (skip-with-reason off-TPU).

One set of pretrained dense weights is pushed through `convert_from` at
stage 0 (dense), stage 1 (binary-linear attention) and stage 2 (+ MoE of
Mult/Shift primitives), then served through the shape-bucketed inference
engine with the deployment freeze on (default) or off (`--no-freeze`).
Default geometry is DeiT-T-like: 196 tokens (56×56 image, patch 4) — the
sequence length the paper's serving claim is made at; `--image-size 32`
reproduces the old toy scale.

Reported per policy: batch latency (median), throughput, analytic per-image
energy (paper Tab. 1 unit energies + DRAM movement), the engine's compile
counts (recompiles_after_warmup must be 0 — gated in CI), the freeze state,
and the latency ratio vs the dense arm (`shiftadd_vs_dense_latency` is the
paper's crossover, gated ≤ 1.0 in the acceptance criteria). `--breakdown`
adds measured attention / MLP-MoE / dispatch / other component rows in
bench_breakdown.py's table style, plus — on MoE arms — `dispatch_global`
(the legacy flattened-co-batch dispatch) and `dispatch_delta`
(per-image − global), so the hot-path cost of the batch-invariant
per-image capacity dispatch stays visible in the BENCH_vit.json trajectory.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.nn.vit import ViTConfig
from repro.serve.vision import policy_sweep

# Reduced geometry for the CPU interpret-mode smoke of the pallas arm: the
# whole tuned-kernel path (table → DeployPlan → frozen engine → pallas_call
# under the interpreter) at a size where interpreting every kernel stays
# cheap. Timings from this geometry are NOT kernel timings.
SMOKE_CFG = dict(image_size=16, patch_size=4, n_layers=2, d_model=32,
                 n_heads=2, d_ff=64)
SMOKE_BATCH, SMOKE_ITERS, SMOKE_BUCKETS = 4, 3, (1, 4)


def pallas_arm(cfg=None, batch=32, iters=10, tune=None):
    """The measured impl=pallas serving arm (nested under "pallas_arm" in
    BENCH_vit.json) plus an impl=xla twin sweep at the SAME geometry — the
    per-bucket pair check_vit_pallas.py gates `pallas <= xla` on.

    mode "tpu": real Pallas kernels at the benchmark geometry, through the
    persisted autotune table when one is given.
    mode "interpret-smoke" (any non-TPU backend): interpreter-executed
    kernels at SMOKE_CFG geometry — proves the serving path end to end, but
    the latency gate must be skipped (check_vit_pallas.py prints the
    carried skip_reason and exits 0).
    """
    import jax

    backend = jax.default_backend()
    if backend == "tpu":
        mode, kernel_impl, skip_reason = "tpu", "pallas", None
        arm_cfg = cfg or ViTConfig(image_size=56)
        arm_batch, arm_iters, arm_buckets = batch, max(iters, 10), None
    else:
        mode, kernel_impl = "interpret-smoke", "interpret"
        skip_reason = (f"backend={backend}: Pallas kernels ran under the "
                       "interpreter at reduced geometry; timings are "
                       "interpreter overhead, not kernel performance")
        arm_cfg = ViTConfig(**SMOKE_CFG)
        arm_batch, arm_iters, arm_buckets = (SMOKE_BATCH, SMOKE_ITERS,
                                             SMOKE_BUCKETS)
    kw = dict(batch=arm_batch, iters=arm_iters, buckets=arm_buckets,
              policies=("shiftadd",), freeze=True)
    rec_pallas = policy_sweep(arm_cfg, impl=kernel_impl, tune=tune, **kw)
    rec_xla = policy_sweep(arm_cfg, impl="xla", tune=None, **kw)
    return {
        "mode": mode,
        "backend": backend,
        "impl": kernel_impl,
        "tuned": tune is not None,
        "skip_reason": skip_reason,
        "geometry": {"image_size": arm_cfg.image_size,
                     "n_layers": arm_cfg.n_layers,
                     "d_model": arm_cfg.d_model,
                     "batch": arm_batch, "iters": arm_iters,
                     "buckets": rec_pallas.get("buckets")},
        "pallas": rec_pallas,
        "xla": rec_xla,
    }


def main(rows=None):
    if rows is not None:
        # benchmarks/run.py harness mode: tiny geometry, CSV row contract.
        from repro.nn.vit import ViTConfig as _Cfg
        rec = policy_sweep(_Cfg(image_size=16, patch_size=4, n_layers=2,
                                d_model=32, n_heads=2, d_ff=64),
                           batch=8, iters=2, buckets=(8,))
        for name, r in rec["policies"].items():
            rows.append((f"vit_serve_{name}", r["latency_s_per_batch"] * 1e6,
                         f"img_s={r['images_per_s']:.1f}"))
        return

    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--image-size", type=int, default=56,
                    help="56 → 196 tokens at patch 4 (DeiT-T-like)")
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--impl", choices=["xla", "pallas", "interpret"],
                    default=None,
                    help="force the kernel implementation (CI uses this to "
                         "exercise the interpret path)")
    ap.add_argument("--tune", default=None, metavar="TUNE_kernels.json",
                    help="persisted autotune table (launch/autotune.py "
                         "output); tuned block caps feed every pallas/"
                         "interpret kernel call, the pallas_arm included")
    ap.add_argument("--skip-pallas-arm", action="store_true",
                    help="omit the nested impl=pallas arm (it adds two "
                         "extra sweeps)")
    ap.add_argument("--no-freeze", action="store_true",
                    help="serve the live params instead of the DeployPlan "
                         "(the A/B arm of the freeze benchmark)")
    ap.add_argument("--ab-freeze", action="store_true",
                    help="run the interleaved frozen-vs-live A/B of the "
                         "shiftadd arm instead of the policy sweep (the CI "
                         "freeze gate's measurement; noise-robust)")
    ap.add_argument("--breakdown", action="store_true",
                    help="add measured attention/MLP-MoE/dispatch/other rows")
    ap.add_argument("--out", default=None,
                    help="output path (default: BENCH_vit.json, or "
                         "BENCH_vit_freeze_ab.json under --ab-freeze)")
    args = ap.parse_args()
    if args.out is None:
        name = "BENCH_vit_freeze_ab.json" if args.ab_freeze else "BENCH_vit.json"
        args.out = os.path.join(os.path.dirname(__file__), "..", name)

    # NOTE: --impl threads explicitly through policy_sweep → engine → kernel
    # ops (never via ops.set_default_impl — the old process-global override
    # leaked into every later engine in the process; satellite bugfix).
    tune = None
    if args.tune:
        from repro.kernels import autotune
        tune = autotune.load_table(args.tune)
        if tune is None:
            print(f"WARNING: could not load tune table {args.tune}; "
                  f"serving with default block caps")

    cfg = ViTConfig(image_size=args.image_size, n_layers=args.layers,
                    d_model=args.d_model, d_ff=2 * args.d_model)
    if args.ab_freeze:
        from repro.serve.vision import freeze_ab
        rec = freeze_ab(cfg, batch=args.batch, iters=max(args.iters, 15))
        with open(args.out, "w") as f:
            json.dump(rec, f, indent=2)
        print(f"freeze A/B ({rec['policy']}): frozen "
              f"{rec['frozen_latency_s'] * 1e3:.2f} ms vs live "
              f"{rec['live_latency_s'] * 1e3:.2f} ms "
              f"({rec['frozen_vs_live']:.3f}x, interleaved, "
              f"recompiles={rec['recompiles_after_warmup']})")
        print(f"wrote {os.path.abspath(args.out)}")
        return
    rec = policy_sweep(cfg, batch=args.batch, iters=args.iters,
                       freeze=not args.no_freeze, impl=args.impl,
                       tune=tune, breakdown=args.breakdown)
    if not args.skip_pallas_arm:
        rec["pallas_arm"] = pallas_arm(cfg, batch=args.batch,
                                       iters=args.iters, tune=tune)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)

    dense = rec["policies"]["dense"]
    for name, r in rec["policies"].items():
        lat = r["latency"]
        print(f"{name:>9}: {r['latency_s_per_batch'] * 1e3:8.2f} ms/batch  "
              f"p50/p95/p99 {lat['p50_s'] * 1e3:.2f}/{lat['p95_s'] * 1e3:.2f}"
              f"/{lat['p99_s'] * 1e3:.2f} ms  "
              f"{r['images_per_s']:9.1f} img/s  "
              f"{r['energy_pj_per_image'] / 1e6:8.3f} uJ/img  "
              f"({r['latency_vs_dense']:.2f}x dense latency, "
              f"{r['energy_pj_per_image'] / dense['energy_pj_per_image']:.2f}x "
              f"dense energy, frozen={r['frozen']}, buckets={r['buckets']}, "
              f"waste={r['padding_waste']:.3f}, "
              f"recompiles={r['recompiles_after_warmup']})")
    if args.breakdown:
        # bench_breakdown.py row style: name, microseconds, notes. The
        # additive split is attention + mlp_moe + other; dispatch is a
        # SUBSET of mlp_moe (routing machinery re-measured in isolation),
        # so its row is annotated as such rather than given a fraction.
        # dispatch_global re-measures the LEGACY flattened-co-batch
        # dispatch; the delta row is what the per-image batch-invariance
        # refactor costs (+) or saves (−) on the hot path per batch.
        for name, r in rec["policies"].items():
            bd = r["breakdown"]
            for comp in ("attention", "mlp_moe", "other"):
                frac = bd[f"{comp}_s"] / bd["total_s"] if bd["total_s"] else 0
                print(",".join(str(c) for c in (
                    f"serve_{name}_{comp}", bd[f"{comp}_s"] * 1e6,
                    f"fraction_of_total={frac:.2f}")))
            print(",".join(str(c) for c in (
                f"serve_{name}_dispatch", bd["dispatch_s"] * 1e6,
                "subset_of_mlp_moe;per_image_capacities")))
            if bd["dispatch_global_s"]:
                print(",".join(str(c) for c in (
                    f"serve_{name}_dispatch_global",
                    bd["dispatch_global_s"] * 1e6,
                    "legacy_flattened_co_batch_capacities")))
                print(",".join(str(c) for c in (
                    f"serve_{name}_dispatch_delta",
                    bd["dispatch_delta_s"] * 1e6,
                    "per_image_minus_global")))
    if "shiftadd_vs_dense_latency" in rec:
        print(f"shiftadd vs dense latency: "
              f"{rec['shiftadd_vs_dense_latency']:.3f}x (frozen={rec['frozen']})")
    if "pallas_arm" in rec:
        arm = rec["pallas_arm"]
        p = arm["pallas"]["policies"]["shiftadd"]["latency"]
        x = arm["xla"]["policies"]["shiftadd"]["latency"]
        print(f"pallas arm [{arm['mode']}]: pallas p50 "
              f"{p['p50_s'] * 1e3:.2f} ms vs xla p50 "
              f"{x['p50_s'] * 1e3:.2f} ms (tuned={arm['tuned']})")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
