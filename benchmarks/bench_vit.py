"""ShiftAddViT policy-sweep serving benchmark. Writes BENCH_vit.json so the
paper's headline claim (latency + energy reduction vs the dense ViT) has a
per-PR trajectory, next to BENCH_serve.json's LM numbers.

    PYTHONPATH=src python benchmarks/bench_vit.py [--batch 32]

One set of pretrained dense weights is pushed through `convert_from` at
stage 0 (dense), stage 1 (binary-linear attention) and stage 2 (+ MoE of
Mult/Shift primitives), then served through the shape-bucketed inference
engine. Reported per policy: batch latency, throughput, analytic per-image
energy (paper Tab. 1 unit energies + DRAM movement), and the engine's
compile counts (recompiles_after_warmup must be 0 — asserted in
tests/test_vision_serve.py).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.nn.vit import ViTConfig
from repro.serve.vision import policy_sweep


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--image-size", type=int, default=32)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_vit.json"))
    args = ap.parse_args()

    cfg = ViTConfig(image_size=args.image_size, n_layers=args.layers,
                    d_model=args.d_model, d_ff=2 * args.d_model)
    rec = policy_sweep(cfg, batch=args.batch, iters=args.iters)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)

    dense = rec["policies"]["dense"]
    for name, r in rec["policies"].items():
        print(f"{name:>9}: {r['latency_s_per_batch'] * 1e3:8.2f} ms/batch  "
              f"{r['images_per_s']:9.1f} img/s  "
              f"{r['energy_pj_per_image'] / 1e6:8.3f} uJ/img  "
              f"({r['energy_pj_per_image'] / dense['energy_pj_per_image']:.2f}x "
              f"dense energy, recompiles={r['recompiles_after_warmup']})")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
