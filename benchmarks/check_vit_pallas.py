"""CI gate for the measured impl=pallas serving arm (vit-serve/vit-traffic).

    python benchmarks/check_vit_pallas.py BENCH_vit.json [BENCH_traffic.json]

Reads the nested `pallas_arm` record bench_vit.py / bench_traffic.py attach
(an impl=pallas sweep next to an impl=xla twin at the same geometry, fed
through the persisted autotune table) and gates, mirroring how
check_vit_freeze.py gates frozen <= unfrozen:

- FAILS (exit 1) if a record has NO `pallas_arm` — a benchmark that stopped
  producing the arm must not pass by omission;
- FAILS if any pallas-arm engine recompiled after warmup;
- on a real-kernel arm (mode == "tpu"): FAILS if the pallas arm is slower
  than the xla twin beyond NOISE_MARGIN — per bucket, on the
  `bucket_latency` series for BENCH_vit.json, on per-request latency for
  BENCH_traffic.json — compared at the percentile the sample count supports
  (serve.metrics.gate_percentile: p99 needs n >= 100, p95 n >= 20, else
  p50; nearest-rank observed samples, never interpolated);
- on an interpret-smoke arm (any non-TPU backend): the latency gate is
  SKIPPED WITH THE CARRIED REASON printed — interpreter timings say nothing
  about kernel performance — and the check exits 0 provided the arm exists,
  ran the shiftadd policy, and recompiled nothing. A skip is always loud,
  never a silent pass.

Harness mode (`benchmarks/run.py` → main(rows)): builds the interpret-smoke
arm in-process and runs the same gate logic over it, so the gate's own code
path is exercised on CPU-only runners every harness run.
"""
from __future__ import annotations

import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.serve.metrics import gate_percentile

NOISE_MARGIN = 1.05


def _arm_failures(arm, label, failures, skips):
    """Gate one nested pallas_arm record; append to failures/skips."""
    if not isinstance(arm, dict) or "pallas" not in arm:
        failures.append(f"{label}: no pallas_arm record — the benchmark "
                        f"did not produce the impl=pallas arm")
        return
    for side in ("pallas", "xla"):
        for name, r in arm[side].get("policies", {}).items():
            if r.get("recompiles_after_warmup", 1) > 0:
                failures.append(
                    f"{label}/{side}/{name}: recompiled after warmup "
                    f"({r.get('recompiles_after_warmup')} extra traces)")
    p_pol = arm["pallas"].get("policies", {}).get("shiftadd")
    x_pol = arm["xla"].get("policies", {}).get("shiftadd")
    if p_pol is None or x_pol is None:
        failures.append(f"{label}: pallas_arm is missing the shiftadd "
                        f"policy on one side")
        return
    if arm.get("mode") != "tpu":
        skips.append(f"{label}: latency gate skipped — "
                     f"{arm.get('skip_reason') or 'non-TPU backend'}")
        return

    # Real kernels: pallas must be at-or-below the xla twin. Per bucket
    # when the record carries the per-bucket series (BENCH_vit.json),
    # else on the arm's request/batch latency (BENCH_traffic.json).
    p_buckets = p_pol.get("bucket_latency") or {}
    x_buckets = x_pol.get("bucket_latency") or {}
    pairs = ([(f"bucket {b}", p_buckets[b], x_buckets[b])
              for b in sorted(p_buckets, key=int) if b in x_buckets]
             or [("latency", p_pol["latency"], x_pol["latency"])])
    for where, p_lat, x_lat in pairs:
        key = gate_percentile(min(p_lat["n"], x_lat["n"]))
        if x_lat[key] <= 0:
            failures.append(f"{label}/{where}: xla twin reports "
                            f"non-positive {key}")
            continue
        ratio = p_lat[key] / x_lat[key]
        print(f"{label}/{where}: pallas {p_lat[key] * 1e3:.3f} ms vs xla "
              f"{x_lat[key] * 1e3:.3f} ms at {key} "
              f"(n={min(p_lat['n'], x_lat['n'])}, {ratio:.3f}x, "
              f"tuned={arm.get('tuned')})")
        if ratio > NOISE_MARGIN:
            failures.append(
                f"{label}/{where}: pallas is slower than the xla twin at "
                f"{key} ({ratio:.3f}x > {NOISE_MARGIN}x noise margin)")


def check_records(records):
    """records: {label: BENCH record dict}. Returns exit code."""
    failures, skips = [], []
    for label, rec in records.items():
        _arm_failures(rec.get("pallas_arm"), label, failures, skips)
    for s in skips:
        print(f"SKIP: {s}")
    for f in failures:
        print(f"FAIL: {f}")
    if failures:
        return 1
    print("pallas gate OK" + (" (latency gate skipped off-TPU)"
                              if skips else ""))
    return 0


def main(rows=None):
    if rows is not None:
        # benchmarks/run.py harness mode: build the interpret-smoke arm
        # in-process and push it through the real gate path.
        import time

        from benchmarks import bench_vit

        t0 = time.time()
        arm = bench_vit.pallas_arm(tune=None)
        code = check_records({"smoke": {"pallas_arm": arm}})
        p50 = arm["pallas"]["policies"]["shiftadd"]["latency"]["p50_s"]
        rows.append(("check_vit_pallas", (time.time() - t0) * 1e6,
                     f"mode={arm['mode']};gate_exit={code};"
                     f"pallas_p50_us={p50 * 1e6:.0f}"))
        if code != 0:
            raise SystemExit("check_vit_pallas harness gate failed")
        return

    argv = sys.argv
    if len(argv) < 2:
        print(__doc__)
        return 2
    records = {os.path.basename(p): json.load(open(p)) for p in argv[1:]}
    return check_records(records)


if __name__ == "__main__":
    sys.exit(main())
