"""Traffic-serving benchmark: one seeded arrival trace, every policy arm.
Writes BENCH_traffic.json — the per-REQUEST twin of BENCH_vit.json's
per-batch numbers, sharing its latency-summary schema (serve.metrics).

    PYTHONPATH=src python benchmarks/bench_traffic.py [--requests 300]
    PYTHONPATH=src python benchmarks/bench_traffic.py --scenario bursty

The trace (arrival rate, deadline budgets) is calibrated from the DENSE
arm's measured per-bucket service times at --utilization of its replica
capacity, then replayed unchanged against each policy — so
`shiftadd_vs_dense_p99` compares the same requests, same arrivals, same
deadlines, and reflects purely how much faster the reparameterized engine
drains the queue. CI gates (benchmarks/check_traffic.py): zero recompiles
after warmup, zero deadline misses at the calibrated default load, shiftadd
p99 at or below dense p99, bit-identical seeded replay on EVERY arm
(shiftadd's MoE included — per-image capacity dispatch made it
batch-invariant), and 1-vs-N-replica bit-identical per-request logits under
diverging batch compositions (`one_vs_n_bit_identical_logits`). The sweep
also carries the telemetry-trained `router` arm (shiftadd weights, router
fine-tuned on measured per-expert latencies — serve.telemetry +
train.router_tune), gated router p99 ≤ shiftadd p99 with increased shift
expert token share.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.nn.vit import ViTConfig
from repro.serve.frontend import traffic_sweep
from repro.serve.traffic import SCENARIOS


def run(scenario="poisson", requests=300, seed=0, replicas=2, arm="auto",
        utilization=0.4, image_size=56, layers=4, d_model=128, impl=None,
        tune=None, verify_replay=True, verify_one_vs_n=True, telemetry=None,
        router_steps=40):
    # "router" is the telemetry-trained arm: shiftadd weights, measured
    # per-expert latencies (TELEMETRY_experts.json or in-process probes),
    # router fine-tuned against them (serve.frontend docstring).
    cfg = ViTConfig(image_size=image_size, n_layers=layers, d_model=d_model,
                    d_ff=2 * d_model)
    return traffic_sweep(
        cfg, scenario=scenario,
        policies=("dense", "stage1", "shiftadd", "router"),
        n_requests=requests, seed=seed, replicas=replicas, arm=arm,
        utilization=utilization, impl=impl, tune=tune,
        verify_replay=verify_replay, verify_one_vs_n=verify_one_vs_n,
        telemetry=telemetry, router_steps=router_steps)


def pallas_arm(scenario="poisson", requests=300, seed=0, tune=None,
               image_size=56, layers=4, d_model=128):
    """Nested `pallas_arm` traffic record: the shiftadd arm served at
    impl=pallas next to an impl=xla twin on the SAME trace geometry.

    TPU: real kernels at the CLI geometry. Elsewhere: interpret-mode smoke
    at bench_vit.SMOKE_CFG-scale traffic (40 requests, 16px, 2 layers) —
    path proof only; check_vit_pallas.py skips the latency gate with the
    carried reason.
    """
    import jax

    backend = jax.default_backend()
    if backend == "tpu":
        mode, kernel_impl, skip_reason = "tpu", "pallas", None
        geo = dict(image_size=image_size, layers=layers, d_model=d_model)
        n_req = requests
    else:
        mode, kernel_impl = "interpret-smoke", "interpret"
        skip_reason = (f"backend={backend}: Pallas kernels ran under the "
                       "interpreter at reduced traffic geometry; timings "
                       "are interpreter overhead, not kernel performance")
        geo = dict(image_size=16, layers=2, d_model=32)
        n_req = 40
    cfg = ViTConfig(image_size=geo["image_size"], n_layers=geo["layers"],
                    d_model=geo["d_model"], d_ff=2 * geo["d_model"])
    common = dict(scenario=scenario, policies=("shiftadd",),
                  n_requests=n_req, seed=seed, replicas=1, arm="thread",
                  verify_replay=False, verify_one_vs_n=False)
    rec_pallas = traffic_sweep(cfg, impl=kernel_impl, tune=tune, **common)
    rec_xla = traffic_sweep(cfg, impl="xla", tune=None, **common)
    return {
        "mode": mode,
        "backend": backend,
        "impl": kernel_impl,
        "tuned": tune is not None,
        "skip_reason": skip_reason,
        "geometry": dict(geo, requests=n_req),
        "pallas": rec_pallas,
        "xla": rec_xla,
    }


def main(rows=None):
    if rows is not None:
        # benchmarks/run.py harness mode: tiny geometry, CSV row contract.
        rec = run(requests=40, image_size=16, layers=2, d_model=32,
                  verify_replay=False, verify_one_vs_n=False)
        for name, r in rec["policies"].items():
            rows.append((f"traffic_{name}_p99", r["latency"]["p99_s"] * 1e6,
                         f"goodput_img_s={r['goodput_images_per_s']:.1f}"))
        return

    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="poisson", choices=SCENARIOS)
    ap.add_argument("--requests", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--arm", default="auto",
                    choices=["auto", "thread", "sharded"])
    ap.add_argument("--utilization", type=float, default=0.4)
    ap.add_argument("--image-size", type=int, default=56)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--impl", choices=["xla", "pallas", "interpret"],
                    default=None)
    ap.add_argument("--tune", default=None, metavar="TUNE_kernels.json",
                    help="persisted autotune table (launch/autotune.py "
                         "output)")
    ap.add_argument("--telemetry", default=None,
                    metavar="TELEMETRY_experts.json",
                    help="persisted expert telemetry (launch/tune_router.py "
                         "output) for the router arm; absent/invalid → "
                         "extracted in-process (fail-open)")
    ap.add_argument("--router-steps", type=int, default=40,
                    help="router fine-tune steps for the telemetry arm")
    ap.add_argument("--skip-pallas-arm", action="store_true",
                    help="omit the nested impl=pallas traffic arm")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_traffic.json")
    # --impl threads explicitly through traffic_sweep → replicas → engines
    # (never via ops.set_default_impl; satellite bugfix).
    tune = None
    if args.tune:
        from repro.kernels import autotune
        tune = autotune.load_table(args.tune)
        if tune is None:
            print(f"WARNING: could not load tune table {args.tune}; "
                  f"serving with default block caps")

    telemetry = None
    if args.telemetry:
        from repro.serve.telemetry import load_telemetry
        telemetry = load_telemetry(args.telemetry)
        if telemetry is None:
            print(f"WARNING: could not load telemetry {args.telemetry}; "
                  f"the router arm will extract its own probes")

    rec = run(scenario=args.scenario, requests=args.requests, seed=args.seed,
              replicas=args.replicas, arm=args.arm,
              utilization=args.utilization, image_size=args.image_size,
              layers=args.layers, d_model=args.d_model, impl=args.impl,
              tune=tune, telemetry=telemetry, router_steps=args.router_steps)
    if not args.skip_pallas_arm:
        rec["pallas_arm"] = pallas_arm(
            scenario=args.scenario, requests=args.requests, seed=args.seed,
            tune=tune, image_size=args.image_size, layers=args.layers,
            d_model=args.d_model)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    for name, r in rec["policies"].items():
        lat = r["latency"]
        print(f"{name:>9}: p50 {lat['p50_s'] * 1e3:7.1f} ms  "
              f"p95 {lat['p95_s'] * 1e3:7.1f} ms  "
              f"p99 {lat['p99_s'] * 1e3:7.1f} ms  "
              f"goodput {r['goodput_images_per_s']:8.1f} img/s  "
              f"miss {r['deadline_miss_rate']:.3f}  "
              f"waste {r['padding_waste']:.3f}  "
              f"recompiles {r['recompiles_after_warmup']}")
    if "shiftadd_vs_dense_p99" in rec:
        print(f"shiftadd vs dense p99: {rec['shiftadd_vs_dense_p99']:.3f}x")
    if "router_vs_shiftadd_p99" in rec:
        ro = rec["policies"]["router"]
        sa_share = rec["policies"]["shiftadd"].get(
            "expert_token_share", {}).get("shift", 0.0)
        ro_share = ro.get("expert_token_share", {}).get("shift", 0.0)
        print(f"router vs shiftadd p99: "
              f"{rec['router_vs_shiftadd_p99']:.3f}x  "
              f"shift share {sa_share:.3f} → {ro_share:.3f}  "
              f"(alpha source {ro.get('expert_latency_source')}, "
              f"{ro.get('router_steps')} steps)")
    if "pallas_arm" in rec:
        arm = rec["pallas_arm"]
        p = arm["pallas"]["policies"]["shiftadd"]["latency"]
        x = arm["xla"]["policies"]["shiftadd"]["latency"]
        print(f"pallas arm [{arm['mode']}]: pallas p50 "
              f"{p['p50_s'] * 1e3:.2f} ms vs xla p50 "
              f"{x['p50_s'] * 1e3:.2f} ms (tuned={arm['tuned']})")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
