"""Traffic-serving benchmark: one seeded arrival trace, every policy arm.
Writes BENCH_traffic.json — the per-REQUEST twin of BENCH_vit.json's
per-batch numbers, sharing its latency-summary schema (serve.metrics).

    PYTHONPATH=src python benchmarks/bench_traffic.py [--requests 300]
    PYTHONPATH=src python benchmarks/bench_traffic.py --scenario bursty

The trace (arrival rate, deadline budgets) is calibrated from the DENSE
arm's measured per-bucket service times at --utilization of its replica
capacity, then replayed unchanged against each policy — so
`shiftadd_vs_dense_p99` compares the same requests, same arrivals, same
deadlines, and reflects purely how much faster the reparameterized engine
drains the queue. CI gates (benchmarks/check_traffic.py): zero recompiles
after warmup, zero deadline misses at the calibrated default load, shiftadd
p99 at or below dense p99, bit-identical seeded replay on EVERY arm
(shiftadd's MoE included — per-image capacity dispatch made it
batch-invariant), and 1-vs-N-replica bit-identical per-request logits under
diverging batch compositions (`one_vs_n_bit_identical_logits`).
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.nn.vit import ViTConfig
from repro.serve.frontend import traffic_sweep
from repro.serve.traffic import SCENARIOS


def run(scenario="poisson", requests=300, seed=0, replicas=2, arm="auto",
        utilization=0.4, image_size=56, layers=4, d_model=128, impl=None,
        verify_replay=True, verify_one_vs_n=True):
    cfg = ViTConfig(image_size=image_size, n_layers=layers, d_model=d_model,
                    d_ff=2 * d_model)
    return traffic_sweep(
        cfg, scenario=scenario, policies=("dense", "stage1", "shiftadd"),
        n_requests=requests, seed=seed, replicas=replicas, arm=arm,
        utilization=utilization, impl=impl, verify_replay=verify_replay,
        verify_one_vs_n=verify_one_vs_n)


def main(rows=None):
    if rows is not None:
        # benchmarks/run.py harness mode: tiny geometry, CSV row contract.
        rec = run(requests=40, image_size=16, layers=2, d_model=32,
                  verify_replay=False, verify_one_vs_n=False)
        for name, r in rec["policies"].items():
            rows.append((f"traffic_{name}_p99", r["latency"]["p99_s"] * 1e6,
                         f"goodput_img_s={r['goodput_images_per_s']:.1f}"))
        return

    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="poisson", choices=SCENARIOS)
    ap.add_argument("--requests", type=int, default=300)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--arm", default="auto",
                    choices=["auto", "thread", "sharded"])
    ap.add_argument("--utilization", type=float, default=0.4)
    ap.add_argument("--image-size", type=int, default=56)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=128)
    ap.add_argument("--impl", choices=["xla", "pallas", "interpret"],
                    default=None)
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    if args.out is None:
        args.out = os.path.join(os.path.dirname(__file__), "..",
                                "BENCH_traffic.json")
    if args.impl:
        from repro.kernels import ops
        ops.set_default_impl(args.impl)

    rec = run(scenario=args.scenario, requests=args.requests, seed=args.seed,
              replicas=args.replicas, arm=args.arm,
              utilization=args.utilization, image_size=args.image_size,
              layers=args.layers, d_model=args.d_model, impl=args.impl)
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    for name, r in rec["policies"].items():
        lat = r["latency"]
        print(f"{name:>9}: p50 {lat['p50_s'] * 1e3:7.1f} ms  "
              f"p95 {lat['p95_s'] * 1e3:7.1f} ms  "
              f"p99 {lat['p99_s'] * 1e3:7.1f} ms  "
              f"goodput {r['goodput_images_per_s']:8.1f} img/s  "
              f"miss {r['deadline_miss_rate']:.3f}  "
              f"waste {r['padding_waste']:.3f}  "
              f"recompiles {r['recompiles_after_warmup']}")
    if "shiftadd_vs_dense_p99" in rec:
        print(f"shiftadd vs dense p99: {rec['shiftadd_vs_dense_p99']:.3f}x")
    print(f"wrote {os.path.abspath(args.out)}")


if __name__ == "__main__":
    main()
